"""Request router (repro.serve.router): replay determinism, health-aware
shedding, session-affinity hit accounting, demand shaping, and the
scheduler-level differential (``random`` on a single-pod fleet must
reproduce the unrouted numbers bit-for-bit)."""
import math

import numpy as np
import pytest

from repro.serve.router import (
    AFFINITY_POLICIES,
    POLICIES,
    Router,
    partition_edges,
)
from repro.sim import SimConfig, Simulator, serving_job


# ---------------------------------------------------------------------------
# replay purity / determinism
# ---------------------------------------------------------------------------

def _arrivals(n=400, span=600.0, seed=5):
    rng = np.random.default_rng(seed)
    return np.sort(rng.uniform(0.0, span, size=n))


POOL_LOG = [(0.0, (2, 3, 4)), (200.0, (2, 4)), (400.0, (2, 3, 4))]
PHI_TLS = {
    2: [(0.0, 1.0), (100.0, 0.5), (300.0, 1.0)],
    3: [(0.0, 1.0), (150.0, 0.0), (250.0, 0.25)],
    4: [(0.0, 1.0)],
}


@pytest.mark.parametrize("policy", POLICIES)
def test_replay_is_pure_and_seed_deterministic(policy):
    arr = _arrivals()
    r = Router(policy, seed=(0, 7))
    a = r.replay(arr, POOL_LOG, PHI_TLS)
    b = r.replay(arr, POOL_LOG, PHI_TLS)  # same router, second call
    c = Router(policy, seed=(0, 7)).replay(arr, POOL_LOG, PHI_TLS)
    for other in (b, c):
        np.testing.assert_array_equal(a.pods, other.pods)
        np.testing.assert_array_equal(a.hits, other.hits)
        assert a.stats == other.stats
    # a different seed must actually change something for the policies
    # that consume randomness
    if policy != "round_robin":
        d = Router(policy, seed=(1, 8)).replay(arr, POOL_LOG, PHI_TLS)
        assert not np.array_equal(a.pods, d.pods) or policy == "round_robin"
    # every request routed somewhere inside the pool
    assert set(np.unique(a.pods)) <= {2, 3, 4}
    assert a.stats["hits"] + a.stats["misses"] == a.stats["requests"]


def test_sessions_policy_independent():
    """The session stream depends only on the seed — never on the
    policy — so hit-rate comparisons across policies are apples-to-
    apples."""
    arr = _arrivals()
    streams = []
    for policy in POLICIES:
        r = Router(policy, seed=42)
        rng = np.random.default_rng(r.seed)
        rng.integers(0, np.iinfo(np.int64).max, size=arr.size)
        streams.append(r._sessions(arr.size, rng))
    for s in streams[1:]:
        np.testing.assert_array_equal(streams[0], s)


# ---------------------------------------------------------------------------
# health-aware shedding
# ---------------------------------------------------------------------------

def test_topology_aware_avoids_dark_and_cordoned_pods():
    """While a healthy alternative exists, no request lands on a φ = 0
    pod or a cordoned pod."""
    arr = _arrivals(n=600)
    pool_log = [(0.0, (2, 3, 4))]
    tls = {2: [(0.0, 1.0)], 3: [(0.0, 0.0)], 4: [(0.0, 1.0)]}  # 3 dark
    cordons = {4: [(0.0, 2.0)]}  # 4 cordoned the whole run
    res = Router("topology_aware", seed=1).replay(
        arr, pool_log, tls, cordons
    )
    assert set(np.unique(res.pods)) == {2}
    assert res.stats["sheds"] > 0
    # once pod 3 recovers, load returns to it
    tls_rec = {**tls, 3: [(0.0, 0.0), (300.0, 1.0)]}
    res2 = Router("topology_aware", seed=1).replay(
        arr, pool_log, tls_rec, cordons
    )
    late = res2.pods[arr > 300.0]
    assert 3 in set(np.unique(late))
    assert 4 not in set(np.unique(res2.pods))


def test_topology_aware_all_unhealthy_falls_back():
    """With every pod dark the router still routes (nothing healthier
    exists to shed toward)."""
    arr = _arrivals(n=50)
    tls = {2: [(0.0, 0.0)], 3: [(0.0, 0.0)]}
    res = Router("topology_aware", seed=1).replay(
        arr, [(0.0, (2, 3))], tls
    )
    assert (res.pods >= 0).all()


# ---------------------------------------------------------------------------
# session-affinity hit accounting
# ---------------------------------------------------------------------------

def test_affinity_hit_accounting():
    arr = _arrivals(n=2000)
    for policy in POLICIES:
        res = Router(policy, seed=9).replay(arr, [(0.0, (2, 3, 4))], {})
        if policy in AFFINITY_POLICIES:
            # geometric sessions with mean 8 → most requests re-find
            # their pinned pod; a stable pool never breaks a pin
            assert 0.5 < res.stats["hit_rate"] < 1.0
            # a hit means: same session seen before, previous request on
            # the same pod — verify against a direct per-session scan
            rng = np.random.default_rng(9)
            rng.integers(0, np.iinfo(np.int64).max, size=arr.size)
            sid = Router(policy, seed=9)._sessions(arr.size, rng)
            last = {}
            for i in range(arr.size):
                expect = last.get(sid[i]) == res.pods[i]
                assert bool(res.hits[i]) == bool(expect), i
                last[sid[i]] = res.pods[i]
        else:
            assert res.stats["hits"] == 0.0
            assert res.stats["hit_rate"] == 0.0
        assert res.stats["hits"] + res.stats["misses"] == arr.size


def test_kv_aware_spills_under_skew():
    """kv_aware caps per-window load: with a working set this small the
    rendezvous pins concentrate, and the overflow must move."""
    arr = np.sort(np.random.default_rng(3).uniform(0, 60.0, size=800))
    r = Router("kv_aware", seed=2, working_set=2, session_mean=50.0,
               overload_factor=1.1)
    res = r.replay(arr, [(0.0, (2, 3, 4, 5))], {})
    assert res.stats["overloads"] > 0
    plain = Router("session_affinity", seed=2, working_set=2,
                   session_mean=50.0).replay(arr, [(0.0, (2, 3, 4, 5))], {})
    # spilling strictly flattens the per-pod histogram
    def spread(pods):
        c = np.bincount(pods)
        return int(c.max())
    assert spread(res.pods) < spread(plain.pods)


# ---------------------------------------------------------------------------
# edge partition + demand shaping
# ---------------------------------------------------------------------------

def test_partition_edges_conserves_demand():
    edges = {(0, 2): 4, (1, 3): 2, (2, 3): 1, (0, 1): 5}
    parts = partition_edges(edges, [2, 3])
    rebuilt = {}
    for sub in parts.values():
        for e, w in sub.items():
            assert e not in rebuilt
            rebuilt[e] = w
    assert rebuilt == edges  # nothing dropped, nothing double-counted
    assert set(parts) <= {2, 3}
    # prefill→prefill edge fell to the lowest decode pod
    assert (0, 1) in parts[2]


def test_demand_weights_topology_only():
    w = Router("topology_aware").demand_weights(
        [2, 3, 4], {2: 1.0, 3: 0.5, 4: 1.0}, {4: 2}
    )
    assert w[4] == 0.0  # cordoned
    assert w[2] > w[3] >= 0.1  # φ headroom, floored
    for policy in POLICIES:
        if policy != "topology_aware":
            assert Router(policy).demand_weights([2], {2: 1.0}, {}) is None
    # everything cordoned → even fallback, never all-zero
    w = Router("topology_aware").demand_weights([2, 3], {}, {2: 1, 3: 1})
    assert w == {2: 1.0, 3: 1.0}


# ---------------------------------------------------------------------------
# scheduler integration
# ---------------------------------------------------------------------------

def _run(router, gpus, seed=11, horizon=400.0):
    cfg = SimConfig(
        "cross_wiring", "mdmcf", num_pods=8, k_spine=8, k_leaf=8,
        engine="fluid", reconfig_delay_s=0.1, router=router,
    )
    j = serving_job(0, gpus, req_rate=20.0, model="mixtral-8x7b",
                    kv_tokens=2048)
    sim = Simulator(cfg, [j], seed=seed)
    sim.run(until=horizon)
    return sim


def test_single_pod_fleet_random_matches_pooled_exactly():
    """A fleet inside one pod has no decode pool: every request falls
    back to the fleet timeline and the unrouted numbers reproduce
    bit-for-bit (``random`` never hits, by construction)."""
    pooled = _run(None, gpus=64).serving_summary()
    routed = _run("random", gpus=64).serving_summary()
    row_p, row_r = pooled["jobs"][0], dict(routed["jobs"][0])
    routing = row_r.pop("routing")
    assert row_r == row_p
    assert routing["hits"] == 0.0
    assert routing["pods_used"] == 0.0  # all fleet-level fallbacks


def test_routed_summary_idempotent_and_conserved():
    """serving_summary() replays routing purely (two calls agree
    exactly), and the blame decomposition still conserves on a routed
    multi-pod run."""
    from repro.obs import attribute_requests

    sim = _run("topology_aware", gpus=320)
    s1 = sim.serving_summary()
    s2 = sim.serving_summary()
    assert s1 == s2
    assert s1["jobs"][0]["routing"]["policy"] == "topology_aware"
    attr = attribute_requests(sim)
    assert attr["conserved"]
    assert attr["max_residual"] <= 1e-6


def test_router_config_validation():
    with pytest.raises(ValueError, match="router"):
        SimConfig("cross_wiring", "mdmcf", num_pods=8, k_spine=8,
                  k_leaf=8, engine="fluid", router="nope")
    with pytest.raises(ValueError):
        Router("nope")
    with pytest.raises(ValueError):
        Router("random", session_mean=0.5)


def test_routed_multi_pod_policies_diverge():
    """On a multi-pod fleet the policy axis is live: affinity policies
    hit, naive ones do not, and per-pod φ timelines exist for the
    decode pods."""
    sim = _run("session_affinity", gpus=320)
    s = sim.serving_summary()
    routing = s["jobs"][0]["routing"]
    assert routing["hit_rate"] > 0.5
    assert routing["pods_used"] >= 2
    pods = {p for _, ps in sim._pool_log[0] for p in ps}
    assert pods and all((0, p) in sim.phi_timeline for p in pods)
    assert routing["kv_bytes_saved"] > 0
