"""Scenario suite: golden summaries, determinism, calibration loop.

Four pillars, matching the suite's contract:

* **Golden byte-identity** — every catalogued scenario's canonical
  summary reproduces the committed bytes under
  ``tests/golden/scenarios/`` exactly, seeded, with the flight recorder
  on or off (tracing is passive).  Per-file diff messages point at the
  single regeneration entrypoint.
* **Compiler invariants** — same spec ⇒ identical jobs and one
  time-sorted event stream; ids positional; autoscale events reference
  real fleets; hypothesis-generated specs (when available) uphold the
  same plus run-level byte-determinism.
* **Cross-engine differential** — ``static_calib`` (contention-free by
  construction) agrees between ``engine="analytic"`` and ``"fluid"`` to
  1e-6 per job; on the faulted scenarios the fluid-only invariant holds:
  incremental reconfiguration never darkens more circuit-seconds than
  cold solves.  (``burst_flap_remediated`` is excluded from the latter:
  its checkpoint-restart recovery makes the two control-plane modes
  diverge into *different trajectories* — restart timing shifts every
  later event — so their dark totals are not comparable; the invariant
  is about identical event sequences priced two ways.)
* **Calibration loop** — per-arch step times derive exactly from the
  committed ``BENCH_step.json`` constants, calibrated profiles carry the
  measured numbers (grad bytes = 2 × params, analytic KV formula), and
  a slow order-of-magnitude guard re-measures one real trainstep so a
  units regression (ms vs s) can never hide behind the goldens.
"""
import dataclasses
import functools
import json
import math
import os

import pytest

from repro.fault.model import ExpandEvent
from repro.scenario import (
    CATALOG,
    SCENARIO_NAMES,
    ScenarioSpec,
    Uncalibrated,
    calibrated_profile,
    compile_scenario,
    get_scenario,
    load_spec,
    measured_archs,
    measured_step_s,
    quick_spec,
    register_calibrated,
    run_scenario,
    spec_from_dict,
)
from repro.sim.serving import ScaleEvent

from tests.golden import regen

REGEN_CMD = "PYTHONPATH=src python -m tests.golden.regen"
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# trajectory-divergent recovery (ckpt_restart): incremental-vs-cold runs
# reorder restarts, so dark totals are not comparable — see module docstring
_INVARIANT_SCENARIOS = tuple(
    n for n in SCENARIO_NAMES
    if CATALOG[n].recovery_policy != "ckpt_restart"
    and CATALOG[n].engine == "fluid"
)


@functools.lru_cache(maxsize=None)
def _run(name):
    """One shared run per catalogued scenario (summary, sim)."""
    return run_scenario(get_scenario(name))


# ---------------------------------------------------------------------------
# golden byte-identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_golden_summary_reproduces(name):
    path = os.path.join(regen.SCENARIO_DIR, f"{name}.json")
    assert os.path.exists(path), (
        f"golden summary {path} missing — regenerate with: {REGEN_CMD}"
    )
    with open(path) as fh:
        golden = fh.read()
    summary, _ = _run(name)
    got = summary.to_json() + "\n"
    if got != golden:
        gd, nd = json.loads(golden), json.loads(got)
        keys = sorted(set(gd) | set(nd))
        drift = [k for k in keys if gd.get(k) != nd.get(k)]
        pytest.fail(
            f"scenario {name!r} drifted from tests/golden/scenarios/"
            f"{name}.json in sections {drift} — if intentional, "
            f"regenerate with: {REGEN_CMD}"
        )


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_golden_summary_tracer_passive(name):
    """The flight recorder must never change a summary byte."""
    from repro.obs import Tracer

    base, _ = _run(name)
    traced, sim = run_scenario(get_scenario(name), tracer=Tracer())
    assert traced.to_json() == base.to_json(), (
        f"scenario {name!r}: attaching a Tracer changed the summary — "
        "tracing must stay passive"
    )
    assert sim.trace.enabled and len(sim.trace.events()) > 0


# ---------------------------------------------------------------------------
# compiler invariants
# ---------------------------------------------------------------------------

def _check_compiled(spec):
    comp_a = compile_scenario(spec)
    comp_b = compile_scenario(spec)
    assert comp_a.jobs == comp_b.jobs, "job stream not deterministic"
    assert comp_a.events == comp_b.events, "event stream not deterministic"
    times = [e.time for e in comp_a.events]
    assert times == sorted(times), "event stream not time-sorted"
    assert all(0.0 <= t for t in times)
    for n, j in enumerate(comp_a.jobs):
        assert j.job_id == n, "job ids must be positional"
    serve_ids = {j.job_id for j in comp_a.jobs if j.kind == "serve"}
    for e in comp_a.events:
        if isinstance(e, ScaleEvent):
            assert e.job_id in serve_ids, "autoscale targets a non-fleet"
        if isinstance(e, ExpandEvent):
            assert comp_a.cfg.active_pods is not None
            assert max(e.pods) < spec.num_pods
    return comp_a


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_compile_deterministic_and_ordered(name):
    _check_compiled(get_scenario(name))


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_blame_conservation(name):
    summary, _ = _run(name)
    blame = summary.table["blame"]
    assert blame["conserved"] is True
    assert blame["max_residual"] <= 1e-6


@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_quick_twin_runs(name):
    """The CI-smoke twin preserves the composition and still runs."""
    spec = get_scenario(name)
    q = quick_spec(spec)
    assert (q.chaos is None) == (spec.chaos is None)
    assert q.remediation == spec.remediation
    assert q.router == spec.router
    assert len(q.fleets) == len(spec.fleets)
    summary, _ = run_scenario(q)
    assert summary.table["blame"]["max_residual"] <= 1e-6


# ---------------------------------------------------------------------------
# hypothesis property tests (clear skip when hypothesis is absent)
# ---------------------------------------------------------------------------

def _spec_strategy(st):
    from repro.scenario import FleetSpec

    return st.builds(
        ScenarioSpec,
        name=st.just("prop"),
        days=st.floats(0.02, 0.1),
        seed=st.integers(0, 2**16),
        num_train_jobs=st.integers(2, 6),
        workload_level=st.floats(0.2, 0.9),
        num_pods=st.sampled_from([8, 12]),
        reconfig_delay_s=st.sampled_from([0.0, 0.5]),
        expand_pods=st.integers(0, 2),
        fleets=st.lists(
            st.builds(
                FleetSpec,
                req_rate=st.floats(0.01, 0.05),
                diurnal=st.sampled_from([0.0, 0.5]),
                phase_offset_s=st.floats(0.0, 600.0),
                autoscale_pods=st.integers(0, 1),
            ),
            max_size=2,
        ).map(tuple),
    )


def test_property_compile_invariants():
    pytest.importorskip("hypothesis")  # property tests need hypothesis
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    @settings(
        max_examples=8, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(spec=_spec_strategy(st))
    def check(spec):
        _check_compiled(spec)

    check()


def test_property_run_determinism():
    pytest.importorskip("hypothesis")  # property tests need hypothesis
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st
    from repro.obs import Tracer

    @settings(
        max_examples=4, deadline=None,
        suppress_health_check=[HealthCheck.too_slow],
    )
    @given(spec=_spec_strategy(st))
    def check(spec):
        a, _ = run_scenario(spec)
        b, _ = run_scenario(spec)
        c, _ = run_scenario(spec, tracer=Tracer())
        assert a.to_json() == b.to_json()
        assert a.to_json() == c.to_json()
        assert a.table["blame"]["max_residual"] <= 1e-6

    check()


# ---------------------------------------------------------------------------
# cross-engine differential + fluid-only invariant
# ---------------------------------------------------------------------------

def test_static_scenario_engines_agree():
    """Contention-free by construction ⇒ analytic and fluid JCTs match
    to 1e-6 per job (the scenario-level twin of
    ``tests/test_fluid_differential.py``)."""
    spec = get_scenario("static_calib")
    assert spec.spacing == "serial" and spec.chaos is None
    analytic, _ = _run("static_calib")
    fluid, _ = run_scenario(dataclasses.replace(spec, engine="fluid"))
    a, f = analytic.table["train"]["jct"], fluid.table["train"]["jct"]
    assert set(a) == set(f) and a
    for k, v in a.items():
        assert v is not None and f[k] is not None
        assert f[k] == pytest.approx(v, rel=1e-6)


@pytest.mark.parametrize("name", _INVARIANT_SCENARIOS)
def test_incremental_darkens_no_more_than_cold(name):
    spec = get_scenario(name)
    if spec.engine != "fluid":
        pytest.skip("fluid-only invariant")
    _, inc = _run(name) if spec.incremental else run_scenario(spec)
    _, cold = run_scenario(dataclasses.replace(spec, incremental=False))
    assert inc.downtime_circuit_s <= cold.downtime_circuit_s + 1e-9


# ---------------------------------------------------------------------------
# YAML twins
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", SCENARIO_NAMES)
def test_yaml_twin_matches_catalog(name):
    path = os.path.join(REPO, "examples", "scenarios", f"{name}.yaml")
    assert os.path.exists(path), f"missing YAML twin {path}"
    assert load_spec(path) == get_scenario(name), (
        f"examples/scenarios/{name}.yaml drifted from the catalogue — "
        "regenerate it from ScenarioSpec.to_dict()"
    )


def test_spec_dict_round_trip():
    for spec in CATALOG.values():
        assert spec_from_dict(spec.to_dict()) == spec


# ---------------------------------------------------------------------------
# calibration loop
# ---------------------------------------------------------------------------

def test_measured_step_matches_committed_bench():
    with open(os.path.join(REPO, "BENCH_step.json")) as fh:
        rows = {r["arch"]: r for r in json.load(fh)["rows"]}
    archs = measured_archs()
    assert set(archs) == set(rows)
    for arch in archs:
        assert measured_step_s(arch) == rows[arch]["train_ms"] / 1e3


def test_calibrated_profiles_carry_measured_constants():
    from repro.models.registry import ARCHS, smoke_config

    profs = register_calibrated()
    assert set(profs) == set(measured_archs())
    for arch, prof in profs.items():
        n_total, n_active = ARCHS[arch].param_counts()
        _, n_smoke = smoke_config(arch).param_counts()
        assert prof.grad_bytes == 2.0 * n_total
        assert prof.compute_s == pytest.approx(
            measured_step_s(arch) * n_active / n_smoke, rel=1e-12
        )
        assert prof.layers == ARCHS[arch].num_layers
        # registered: arch ids are now valid Job.model names
        from repro.dist.collectives import MODEL_PROFILES
        assert MODEL_PROFILES[arch] == prof


def test_uncalibrated_arch_raises_not_defaults():
    from repro.configs import ARCH_IDS

    unmeasured = sorted(set(ARCH_IDS) - set(measured_archs()))
    assert unmeasured, "every arch measured — drop this guard"
    with pytest.raises(Uncalibrated):
        measured_step_s(unmeasured[0])
    with pytest.raises(Uncalibrated):
        calibrated_profile(unmeasured[0])


def test_calibration_report_round_trips():
    from repro.scenario import calibration_report

    rep = calibration_report()
    for arch, row in rep.items():
        assert row["compute_s"] == pytest.approx(
            row["measured_step_ms"] / 1e3 * row["scale"], rel=1e-9
        )
        assert row["kv_bytes_per_token"] >= 0.0


@pytest.mark.slow
def test_live_trainstep_within_order_of_magnitude():
    """Re-measure one real trainstep and compare against the committed
    constant.  Tolerance is deliberately huge (×25 either way): this is
    a *units* guard — a ms/s mix-up (1000×) or a broken measurement path
    fails; machine speed differences never do."""
    import benchmarks.bench_step as bench_step

    arch = "olmo-1b"
    committed = measured_step_s(arch)
    payload = _bench_one(bench_step, arch)
    live = payload / 1e3
    assert committed / 25 <= live <= committed * 25, (
        f"live {arch} step {live * 1e3:.2f} ms vs committed "
        f"{committed * 1e3:.2f} ms — rerun `python -m benchmarks.bench_step` "
        "and regenerate scenario goldens"
    )


def _bench_one(bench_step, arch):
    """One arch through the exact bench_step measurement path."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    import time

    from repro.launch.mesh import make_host_mesh
    from repro.models import get_api, make_smoke_batch, smoke_config
    from repro.train.optimizer import OptConfig
    from repro.train.trainstep import (
        TrainHparams, make_train_state, make_train_step,
    )

    cfg = smoke_config(arch)
    api = get_api(cfg)
    batch = make_smoke_batch(cfg, rng=np.random.default_rng(0), batch=4, seq=64)
    sds = {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}
    step, *_ = make_train_step(
        api, cfg, OptConfig(), make_host_mesh(), TrainHparams(), sds
    )
    state = make_train_state(api, jax.random.PRNGKey(0))
    jb = {k: jnp.asarray(v) for k, v in batch.items()}
    state, m = step(state, jb)
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(5):
        state, m = step(state, jb)
    jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / 5 * 1e3
