"""Serving engine: greedy generation equals argmax of teacher-forced full
forward; batch independence."""
import jax
import numpy as np
import pytest

from repro.models import get_api, make_smoke_batch, smoke_config
from repro.serve.engine import ServeEngine


@pytest.mark.parametrize("arch", ["olmo-1b", "rwkv6-1.6b", "whisper-small"])
def test_greedy_matches_full_forward(arch):
    cfg = smoke_config(arch)
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    B, S0, new = 2, 8, 6
    rng = np.random.default_rng(1)
    batch = make_smoke_batch(cfg, rng=rng, batch=B, seq=S0)
    inputs = {k: v for k, v in batch.items() if k != "targets"}

    eng = ServeEngine(api, params, batch=B, s_max=S0 + new + 2)
    out = eng.generate(inputs, max_new_tokens=new)
    assert out.shape == (B, new)

    # oracle: extend token-by-token with full prefill each time
    import jax.numpy as jnp

    nv = cfg.vision_tokens if cfg.family == "vlm" else 0
    toks = np.asarray(batch["tokens"])
    for t in range(new):
        full = dict(inputs)
        full["tokens"] = jnp.asarray(toks)
        cache = api.init_cache(B, S0 + new + 2)
        logits, _ = api.prefill(params, full, cache)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        np.testing.assert_array_equal(out[:, t], nxt, err_msg=f"{arch} tok {t}")
        toks = np.concatenate([toks, nxt[:, None]], axis=1)


def test_batch_slots_independent():
    """Each batch row decodes independently (no cross-slot leakage)."""
    cfg = smoke_config("olmo-1b")
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    b2 = make_smoke_batch(cfg, rng=rng, batch=2, seq=8)
    eng2 = ServeEngine(api, params, batch=2, s_max=20)
    out2 = eng2.generate({"tokens": b2["tokens"]}, max_new_tokens=4)
    for row in range(2):
        eng1 = ServeEngine(api, params, batch=1, s_max=20)
        out1 = eng1.generate({"tokens": b2["tokens"][row : row + 1]}, max_new_tokens=4)
        np.testing.assert_array_equal(out1[0], out2[row])
