"""Serving archetype: KV sizing vs the real engine, mixed-trace
determinism, autoscale on the incremental control-plane path, and the
closed-form differential guarantee for static serving scenarios."""
import math

import numpy as np
import pytest

from repro.dist.collectives import AlphaBeta, MODEL_PROFILES
from repro.dist.demand import kv_bytes_per_token, kv_flow, serving_edges
from repro.sim import (
    SimConfig,
    Simulator,
    autoscale_events,
    generate_trace,
    serving_job,
    serving_trace,
)
from repro.sim.serving import ScaleEvent, request_latencies, request_work_s


# ---------------------------------------------------------------------------
# KV-flow byte sizing vs the serving engine's measured comm profile
# ---------------------------------------------------------------------------

def _arch_ids():
    from repro.configs import ARCH_IDS

    return sorted(ARCH_IDS)


@pytest.mark.parametrize("arch", _arch_ids())
def test_kv_bytes_match_engine_comm_profile(arch):
    """The analytic per-token KV size must equal what the real engine
    allocates per cache slot (GQA tensors, MLA compressed latents) — for
    *every* registered architecture.  Architectures whose engine keeps no
    per-token KV state (linear-attention RNNs: fixed-size recurrent
    state) have no profile to pin; they must SKIP visibly, not pass on a
    vacuous 0 == 0."""
    from repro.models import get_api, smoke_config
    from repro.serve.engine import ServeEngine

    cfg = smoke_config(arch)
    api = get_api(cfg)
    # comm_profile only sizes cache pytrees: no params needed
    eng = ServeEngine(api, params=None, batch=2, s_max=32)
    prof = eng.comm_profile()
    if prof["kv_bytes_per_token"] == 0.0:
        assert kv_bytes_per_token(cfg) == 0.0
        pytest.skip(
            f"{arch}: no per-token KV state (fixed-size recurrent cache) "
            "— nothing to pin; the serving path rejects it explicitly"
        )
    assert prof["kv_bytes_per_token"] == pytest.approx(
        kv_bytes_per_token(cfg), rel=0, abs=0
    )
    assert prof["kv_bytes_per_token"] > 0


def test_profile_kv_bytes_formula():
    """Trace-model profiles carry 2·layers·kv_heads·head_dim·dtype bytes."""
    assert kv_bytes_per_token("mixtral-8x7b") == 2 * 32 * 8 * 128 * 2
    assert kv_bytes_per_token("llama2-70b") == 2 * 80 * 8 * 128 * 2
    assert kv_bytes_per_token("unknown-model") == 0.0


def test_kv_flow_scales_with_load():
    """Offered load is reflected in the edge demand until the per-pair
    port budget caps it; pools sharing a pod stay off the OCS."""
    lo = kv_flow("llama2-13b", [0], [1, 2], 8, req_rate=4.0, kv_tokens=2048)
    hi = kv_flow("llama2-13b", [0], [1, 2], 8, req_rate=64.0, kv_tokens=2048)
    assert set(lo) == {(0, 1), (0, 2)}
    assert all(hi[e] > lo[e] for e in lo)
    assert max(hi.values()) <= 8
    assert kv_flow("llama2-13b", [0], [0], 8, 4.0, 2048) == {}


def test_serving_edges_moe_decode_mesh():
    """Pod-spilling MoE fleets add the decode-pool EP all-to-all clique;
    dense fleets stay bipartite."""
    dense = serving_edges("llama2-13b", [0], [1, 2, 3], 8, 16.0, 2048)
    assert all(0 in e for e in dense)
    moe = serving_edges("mixtral-8x7b", [0], [1, 2, 3], 8, 16.0, 2048)
    for a, b in [(1, 2), (1, 3), (2, 3)]:
        assert (a, b) in moe


# ---------------------------------------------------------------------------
# arrival process + mixed-trace determinism
# ---------------------------------------------------------------------------

def test_serving_trace_deterministic_and_rate():
    a1 = serving_trace(2000.0, 5.0, seed=3, diurnal=0.4, period_s=500.0)
    a2 = serving_trace(2000.0, 5.0, seed=3, diurnal=0.4, period_s=500.0)
    np.testing.assert_array_equal(a1, a2)
    assert (np.diff(a1) >= 0).all()
    assert a1[0] >= 0.0 and a1[-1] < 2000.0
    # mean rate within 10% of nominal over a long window
    assert a1.size == pytest.approx(2000.0 * 5.0, rel=0.1)
    with pytest.raises(ValueError):
        serving_trace(100.0, 5.0, diurnal=1.5)


def test_mixed_trace_deterministic_and_train_invariant():
    base = generate_trace(12, num_gpus=512, seed=5)
    m1 = generate_trace(12, num_gpus=512, seed=5, serving_jobs=2)
    m2 = generate_trace(12, num_gpus=512, seed=5, serving_jobs=2)
    assert m1 == m2  # dataclass equality: byte-identical mixed trace
    # the training stream is unchanged by mixing serving fleets in
    assert m1[:12] == base
    serve = [j for j in m1 if j.kind == "serve"]
    assert len(serve) == 2
    assert all(
        j.service_time == math.inf and j.req_rate > 0 for j in serve
    )
    # list position must stay == job_id (the scheduler indexes jobs by id)
    assert all(j.job_id == i for i, j in enumerate(m1))


# ---------------------------------------------------------------------------
# request-latency integration
# ---------------------------------------------------------------------------

def test_request_latencies_piecewise():
    # φ = 1 for 2 s, dark (φ = 0) for 1 s, then φ = 0.5
    tl = [(0.0, 1.0), (2.0, 0.0), (3.0, 0.5)]
    lat = request_latencies(
        np.array([0.0, 1.5, 2.5]), 1.0, tl, alpha_s=0.0
    )
    assert lat[0] == pytest.approx(1.0)  # finished before the window
    # arrived 1.5: 0.5 work done by t=2, stalls to 3, 0.5/0.5=1 s more
    assert lat[1] == pytest.approx(4.0 - 1.5)
    # arrived dark: waits to t=3, then 1.0/0.5 = 2 s
    assert lat[2] == pytest.approx(5.0 - 2.5)
    # empty timeline / never-finishing tail → inf
    assert math.isinf(request_latencies(np.array([0.0]), 1.0, [])[0])
    assert math.isinf(
        request_latencies(np.array([5.0]), 1.0, [(0.0, 1.0), (4.0, 0.0)])[0]
    )


def test_request_latencies_before_start_queue():
    """Requests arriving before the fleet starts wait for the timeline."""
    lat = request_latencies(np.array([0.0]), 1.0, [(10.0, 1.0)], alpha_s=0.0)
    assert lat[0] == pytest.approx(11.0)


# ---------------------------------------------------------------------------
# scheduler integration
# ---------------------------------------------------------------------------

def _cfg(**kw):
    kw.setdefault("engine", "fluid")
    return SimConfig(
        "cross_wiring", "mdmcf", num_pods=8, k_spine=8, k_leaf=8, **kw
    )


def test_static_serving_matches_closed_form():
    """Differential guarantee: one serving fleet, static configuration →
    every request's latency equals the alpha–beta transfer time within
    1e-6 relative."""
    for engine in ("analytic", "fluid"):
        cfg = _cfg(engine=engine)
        job = serving_job(0, 256, model="llama2-13b", req_rate=20.0,
                          kv_tokens=2048)
        sim = Simulator(cfg, [job], seed=0)
        sim.run(until=300.0)
        s = sim.serving_summary()
        work, alpha_s = sim._serving_work[0]
        r = sim.running[0]
        stripe = max(r.edges.values())
        ab = AlphaBeta()
        closed = (
            2048 * MODEL_PROFILES["llama2-13b"].kv_bytes_per_token
            * ab.beta_cross_pod / stripe + ab.alpha_cross_pod
        )
        assert work + alpha_s == pytest.approx(closed, rel=1e-12)
        row = s["jobs"][0]
        assert row["p50_s"] == pytest.approx(closed, rel=1e-6)
        assert row["p99_s"] == pytest.approx(closed, rel=1e-6)
        assert row["max_s"] == pytest.approx(closed, rel=1e-6)
        assert row["goodput"] == 1.0


def test_autoscale_served_by_incremental_delta():
    """Happy path: ScaleEvents reshape a running fleet's demand without a
    mask change — every post-start reconfiguration must be served by
    mdmcf_delta (no cold solve)."""
    cfg = _cfg()
    job = serving_job(0, 128, model="mixtral-8x7b", req_rate=48.0,
                      kv_tokens=2048, diurnal=0.3)
    evs = [ScaleEvent(50.0, 0, 1), ScaleEvent(100.0, 0, 1),
           ScaleEvent(150.0, 0, -1)]
    sim = Simulator(cfg, [job], seed=0, fault_events=evs)
    sim.run(until=200.0)
    s = sim.serving_summary()
    assert s["autoscale_applied"] == 3.0
    # 1 cold solve at fleet start; every scale event rides mdmcf_delta
    assert sim.reconfig_calls == 4
    assert sim.delta_calls == 3
    r = sim.running[0]
    # net +1 decode pod survives the up/up/down cycle
    assert len(r.decode_pods) == len(_pods_of(sim, 0)) - len(r.prefill_pods)


def _pods_of(sim, jid):
    return sim.running[jid].pods


def test_autoscale_events_schedule():
    job = serving_job(3, 128, req_rate=8.0, diurnal=0.5, arrival=100.0)
    evs = autoscale_events(job, 2400.0, period_s=1200.0)
    assert [(e.time, e.pods) for e in evs] == [
        (400.0, 1), (1000.0, -1), (1600.0, 1), (2200.0, -1)
    ]
    assert all(e.job_id == 3 for e in evs)
    # flat load → no autoscaling
    flat = serving_job(4, 128, req_rate=8.0, diurnal=0.0)
    assert autoscale_events(flat, 2400.0, period_s=1200.0) == []


def test_mixed_trace_runs_and_serving_summary():
    """Train + serve coexist: training jobs finish, serving fleets report
    request latencies, and the pooled summary is well-formed."""
    jobs = generate_trace(
        6, num_gpus=512, seed=2, max_job_gpus=64,
        serving_jobs=1, serving_gpus=128, serving_diurnal=0.2,
    )
    cfg = _cfg(reconfig_delay_s=0.01, serving_period_s=600.0)
    sim = Simulator(cfg, jobs, seed=0)
    sim.run(until=1500.0)
    s = sim.serving_summary()
    assert s["requests"] > 0
    assert math.isfinite(s["p99_s"]) and s["p99_s"] >= s["p50_s"]
    assert 0.0 <= s["goodput"] <= 1.0
    # determinism of the whole pipeline
    sim2 = Simulator(cfg, jobs, seed=0)
    sim2.run(until=1500.0)
    assert sim2.serving_summary() == s


def test_serving_survives_pod_failure():
    """A pod failure shrinks the fleet's pools instead of restarting it."""
    from repro.fault import FailureEvent

    cfg = _cfg()
    job = serving_job(0, 256, model="llama2-13b", req_rate=20.0,
                      kv_tokens=2048)
    sim = Simulator(cfg, [job], seed=0)
    sim.run(until=400.0)
    victim = sim.running[0].decode_pods[0]
    sim2 = Simulator(
        cfg, [job], seed=0,
        fault_events=[FailureEvent(200.0, "pod", pod=victim)],
    )
    sim2.run(until=400.0)
    r = sim2.running[0]
    assert victim not in r.pods
    assert r.record.shrinks == 1 and r.record.restarts == 0
    assert r.prefill_pods and r.decode_pods


def test_serving_decode_pool_wipe_reseeds():
    """Losing the entire decode pool must re-seed it from prefill (and
    rebuild the KV flows), not report a perfect φ=1 fleet with no decode
    capacity."""
    from repro.fault import FailureEvent

    cfg = _cfg()
    # prefill_frac=0.6 over 3 pods → prefill=[p0,p1], decode=[p2]
    job = serving_job(0, 192, model="llama2-13b", req_rate=20.0,
                      kv_tokens=2048, prefill_frac=0.6)
    sim = Simulator(cfg, [job], seed=0)
    sim.run(until=400.0)
    victim = sim.running[0].decode_pods[0]
    assert len(sim.running[0].prefill_pods) == 2
    sim2 = Simulator(
        cfg, [job], seed=0,
        fault_events=[FailureEvent(200.0, "pod", pod=victim)],
    )
    sim2.run(until=400.0)
    r = sim2.running[0]
    assert r.prefill_pods and r.decode_pods  # decode re-seeded
    assert victim not in r.pods
    assert r.edges  # KV flows rebuilt over the surviving split


def test_unprofiled_serving_model_rejected():
    """A serving fleet with no KV profile would produce zero-byte
    transfers and meaningless latency metrics — refuse it early."""
    from repro.core.logical import Job

    with pytest.raises(ValueError, match="kv_bytes_per_token"):
        serving_job(0, 128, model="my-custom-13b")
    # hand-built Jobs that bypass serving_job are caught at placement
    raw = Job(0, 128, arrival=0.0, service_time=math.inf,
              model="my-custom-13b", kind="serve", req_rate=10.0,
              kv_tokens=2048)
    sim = Simulator(_cfg(), [raw], seed=0)
    with pytest.raises(ValueError, match="no KV payload"):
        sim.run(until=100.0)


def test_fluid_latency_sensitive_history():
    """Standalone FluidSim records φ timelines for latency-sensitive
    flows, and a static flow's timeline prices requests exactly."""
    from repro.core.reconfig import mdmcf_reconfigure
    from repro.core.topology import ClusterSpec
    from repro.dist.demand import edges_to_matrix
    from repro.sim.fluid import Flow, FluidSim

    spec = ClusterSpec(num_pods=4, k_spine=4, k_leaf=4)
    edges = {(0, 1): 2, (0, 2): 2}
    C = edges_to_matrix(edges, 4, spec.num_ocs_groups)
    config = mdmcf_reconfigure(spec, C).config
    flow = Flow(0, dict(edges), 1.0, work=math.inf,
                latency_sensitive=True)
    sim = FluidSim(spec, "cross_wiring", config, [flow])
    sim.run(until=50.0)
    tl = sim.phi_history[0]
    assert tl and all(p == 1.0 for _, p in tl)
    lat = request_latencies(np.array([1.0, 20.0]), 0.5, tl, alpha_s=0.0)
    np.testing.assert_allclose(lat, 0.5, rtol=1e-9)


# ---------------------------------------------------------------------------
# zero-φ plateau regression + phase decomposition invariants
# ---------------------------------------------------------------------------

def test_request_latencies_zero_phi_plateau_exact_target():
    """Regression: a request whose cumulative-work target lands exactly
    on a zero-φ plateau must wait for the plateau to end, not finish at
    its start (searchsorted(side="left") used to return the plateau's
    own breakpoint, yielding a negative latency)."""
    tl = [(0.0, 1.0), (1.0, 0.0), (3.0, 1.0)]
    # arrival 2.0 sits inside the dark [1, 3) plateau with zero work:
    # the transfer cannot complete before bandwidth returns at t = 3
    lat = request_latencies(np.array([2.0]), 0.0, tl, alpha_s=0.0)
    assert lat[0] == pytest.approx(1.0)
    assert lat[0] >= 0.0

    # the work → 0 limit is continuous: tiny positive work agrees
    lat_eps = request_latencies(np.array([2.0]), 1e-12, tl, alpha_s=0.0)
    assert lat_eps[0] == pytest.approx(1.0, abs=1e-9)

    # zero work in a live segment still finishes instantly...
    assert request_latencies(
        np.array([0.5]), 0.0, tl, alpha_s=0.0
    )[0] == pytest.approx(0.0)
    # ...and zero work after a dead tail never finishes
    assert math.isinf(request_latencies(
        np.array([5.0]), 0.0, [(0.0, 1.0), (4.0, 0.0)], alpha_s=0.0
    )[0])


def test_request_latencies_never_negative_on_plateau_sweep():
    """No arrival × work combination may price below zero on a timeline
    riddled with dark plateaus."""
    tl = [(0.0, 1.0), (1.0, 0.0), (2.0, 0.5), (4.0, 0.0), (6.0, 1.0),
          (8.0, 0.0)]
    arrivals = np.linspace(0.0, 7.5, 151)  # hits every breakpoint
    for work in (0.0, 1e-9, 0.25, 1.0):
        lat = request_latencies(arrivals, work, tl, alpha_s=0.0)
        finite = lat[np.isfinite(lat)]
        assert (finite >= -1e-12).all(), (work, finite.min())


def test_request_phases_sum_invariant_long_timeline():
    """queue + transfer + decode == latency on a long mixed timeline,
    for every finite request (the binary-search window must not drop
    segments)."""
    from repro.sim.serving import request_phases

    rng = np.random.default_rng(0)
    # 500 breakpoints alternating dark / degraded / live
    times = np.cumsum(rng.uniform(0.05, 0.4, size=500))
    phis = rng.choice([0.0, 0.25, 0.5, 1.0], size=500,
                      p=[0.2, 0.3, 0.2, 0.3])
    tl = list(zip(times.tolist(), phis.tolist()))
    arrivals = rng.uniform(0.0, times[-1], size=200)
    lat = request_latencies(arrivals, 0.3, tl, alpha_s=0.01)
    for a, l in zip(arrivals, lat):
        q, x, d = request_phases(float(a), float(l), tl, alpha_s=0.01)
        if math.isfinite(l):
            assert q + x + d == pytest.approx(l, abs=1e-9)
            assert q >= -1e-12 and x >= -1e-12
        else:
            assert math.isinf(q)


def test_split_pools_partition_properties():
    """_split_pools yields a partition: both pools non-empty on ≥ 2-pod
    fleets, prefill GPU share ≥ prefill_frac minus one pod, and the
    union (in id order, no duplicates) reconstructs the fleet."""
    from repro.sim.scheduler import _split_pools

    rng = np.random.default_rng(1)
    for trial in range(50):
        n = int(rng.integers(1, 40))
        pods = {int(p): int(rng.integers(8, 65))
                for p in rng.choice(1000, size=n, replace=False)}
        frac = float(rng.uniform(0.05, 0.95))
        pre, dec = _split_pools(pods, frac)
        assert sorted(pre + dec) == sorted(pods)
        assert not set(pre) & set(dec)
        if n >= 2:
            assert pre and dec
            got = sum(pods[p] for p in pre)
            want = frac * sum(pods.values())
            assert got >= want - max(pods.values())
        else:
            assert dec == []
