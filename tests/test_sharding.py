"""Sharding rules: divisibility guards, spec/shape consistency, ZeRO-1 dim
agreement between specs and the shard_map step."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist.sharding import (
    batch_specs,
    cache_specs,
    param_pspec,
    param_specs,
    zero1_dim,
    zero1_specs,
)
from repro.launch.mesh import make_host_mesh
from repro.models import ARCHS, get_api, smoke_config


def _flat_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((key, leaf))
    return out


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_param_specs_shape_consistent(arch):
    """Every spec must be applicable: ndim match and divisibility by the
    (hypothetical) model-axis size 16 wherever 'model' appears."""
    cfg = ARCHS[arch]  # FULL config — eval_shape only, no allocation
    api = get_api(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    model = 16
    for key, leaf in _flat_with_paths(shapes):
        spec = param_pspec(key, tuple(leaf.shape), model, cfg.moe is not None)
        assert len(spec) <= len(leaf.shape), (key, spec, leaf.shape)
        for dim, axis in enumerate(spec):
            if axis == "model":
                assert leaf.shape[dim] % model == 0, (key, spec, leaf.shape)


def test_mqa_kv_replicated():
    """gemma-2b has 1 kv head: wk/wv output dim 256 divides 16, but kv
    heads don't — heads stay intact because sharding is on the flat dim."""
    cfg = ARCHS["gemma-2b"]
    api = get_api(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    for key, leaf in _flat_with_paths(shapes):
        if key.endswith("mix/wk"):
            spec = param_pspec(key, tuple(leaf.shape), 16, False)
            # kv proj output is num_kv_heads*head_dim = 256; 256 % 16 == 0 →
            # sharded on the flat dim (head_dim splits, not head count)
            assert spec[-1] == "model"


def test_indivisible_dims_degrade_to_replicated():
    """A projection whose output dim does not divide the model axis must
    fall back to replicated (never a compile error)."""
    spec = param_pspec("units/l0/mix/wq", (24, 896, 897), 16, False)
    assert all(a is None for a in spec)
    # whereas a divisible dim is sharded
    spec = param_pspec("units/l0/mix/wq", (24, 896, 896), 16, False)
    assert spec[-1] == "model"


def test_batch_and_cache_specs():
    mesh = make_host_mesh()
    cfg = smoke_config("olmo-1b")
    api = get_api(cfg)
    cache = jax.eval_shape(lambda: api.init_cache(4, 16))
    cspecs = cache_specs(cache, mesh, cfg)
    for s, leaf in zip(
        jax.tree_util.tree_leaves(cspecs, is_leaf=lambda x: isinstance(x, P)),
        jax.tree_util.tree_leaves(cache),
    ):
        assert len(s) <= len(leaf.shape)
    bs = batch_specs(
        {"tokens": jax.ShapeDtypeStruct((8, 16), np.int32)}, mesh
    )
    assert isinstance(bs["tokens"], P)


def test_zero1_specs_match_zero1_dim():
    """The spec builder and the shard_map step must agree on the scatter
    dim for every leaf (they are separately computed)."""
    cfg = ARCHS["qwen2.5-14b"]
    api = get_api(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    model, data = 16, 16
    for key, leaf in _flat_with_paths(shapes):
        d = zero1_dim(key, tuple(leaf.shape), model, data, False)
        base = list(param_pspec(key, tuple(leaf.shape), model, False))
        while len(base) < len(leaf.shape):
            base.append(None)
        if d is not None:
            assert base[d] is None  # never double-shard a dim
            assert leaf.shape[d] % data == 0
