"""Multi-tenant simulator: determinism, conservation, architecture ordering
(Best ≤ Cross Wiring ≤ Uniform on JCT) and trace calibration (eq. 17)."""
import math

import numpy as np
import pytest

from repro.sim import (
    SimConfig,
    Simulator,
    arrival_rate_for,
    generate_trace,
    ilp_time_model,
    summarize,
)
from repro.sim.trace import expected_gpu_seconds


def _trace(n=120, gpus=8192, wl=0.85, seed=0):
    return generate_trace(n, num_gpus=gpus, workload_level=wl, seed=seed)


def test_trace_calibration():
    """Eq. 17: λ · E[k·T] / GPUs == workload level."""
    lam = arrival_rate_for(0.801, 8192)
    assert lam * expected_gpu_seconds() / 8192 == pytest.approx(0.801)


def test_trace_determinism():
    a = _trace(seed=5)
    b = _trace(seed=5)
    assert [(j.arrival, j.num_gpus, j.service_time) for j in a] == [
        (j.arrival, j.num_gpus, j.service_time) for j in b
    ]


def _run(arch, strat, jobs, pods=64, k=8):
    sim = Simulator(
        SimConfig(architecture=arch, strategy=strat, num_pods=pods, k_spine=k, k_leaf=k),
        jobs,
    )
    return sim, sim.run()


def test_all_jobs_complete():
    jobs = _trace(80)
    for arch, strat in [("best", "none"), ("cross_wiring", "mdmcf"), ("uniform", "greedy")]:
        _, recs = _run(arch, strat, jobs)
        assert all(math.isfinite(r.finish) for r in recs), (arch, strat)
        for r in recs:
            assert r.start >= r.job.arrival
            assert r.finish >= r.start + r.job.service_time * 0.999


def test_sim_determinism():
    jobs = _trace(60)
    _, r1 = _run("cross_wiring", "mdmcf", jobs)
    _, r2 = _run("cross_wiring", "mdmcf", jobs)
    assert [(r.start, r.finish) for r in r1] == [(r.start, r.finish) for r in r2]


def test_best_is_lower_bound():
    """No architecture beats the infinite crossbar on any job's JRT."""
    jobs = _trace(80)
    _, best = _run("best", "none", jobs)
    for arch, strat in [("cross_wiring", "mdmcf"), ("uniform", "greedy"), ("clos", "none")]:
        _, recs = _run(arch, strat, jobs)
        for rb, r in zip(best, recs):
            assert r.jrt >= rb.jrt - 1e-6, (arch, r.job.job_id)


def test_cross_wiring_beats_uniform_on_average():
    """The paper's headline ordering at heavy load."""
    jobs = _trace(150, wl=0.9)
    _, cw = _run("cross_wiring", "mdmcf", jobs)
    _, un = _run("uniform", "greedy", jobs)
    assert summarize(cw)["avg_jct"] <= summarize(un)["avg_jct"] + 1e-6


def test_ltrr_cross_wiring_always_one():
    """Thm 4.1 inside the simulator: every reconfiguration realizes the
    (clipped) aggregate demand exactly."""
    jobs = _trace(60, wl=0.9)
    sim, _ = _run("cross_wiring", "mdmcf", jobs)
    assert sim.ltrr_samples, "no reconfigurations happened"
    assert np.min(sim.ltrr_samples) == pytest.approx(1.0)


def test_ilp_time_model_calibration():
    """Matches the paper's Fig 2c anchor: ~435 s at 32k nodes, small <4k."""
    assert ilp_time_model(32768) == pytest.approx(435.0, rel=0.2)
    assert ilp_time_model(4096) < 2.0


def test_reconfig_overhead_in_jwt():
    """ILP-strategy JWT must dominate MDMCF JWT (computation delay)."""
    jobs = _trace(80, wl=0.9)
    _, md = _run("cross_wiring", "mdmcf", jobs)
    _, ilp = _run("cross_wiring", "itv_ilp", jobs)
    assert summarize(ilp)["avg_jwt"] >= summarize(md)["avg_jwt"]
