"""End-to-end system behaviour: launcher control-plane→data-plane handshake,
fault-injected restart continuation, and a subprocess dry-run on a small
forced-device mesh (the 512-device production dry-run runs via
``python -m repro.launch.dryrun``; artifacts live in artifacts/dryrun/)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
ENV = {**os.environ, "PYTHONPATH": os.path.join(REPO, "src")}


def _run(args, timeout=900):
    return subprocess.run(
        [sys.executable, *args],
        cwd=REPO, env=ENV, capture_output=True, text=True, timeout=timeout,
    )


def test_train_launcher_end_to_end(tmp_path):
    """Control plane + data plane + checkpointing through the public CLI."""
    r = _run(
        [
            "-m", "repro.launch.train", "--arch", "olmo-1b", "--smoke",
            "--steps", "12", "--batch", "4", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
        ]
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "[control-plane]" in r.stdout and "LTRR=1.000" in r.stdout
    assert "step    11" in r.stdout
    # checkpoints were written
    assert any(f.startswith("step_") for f in os.listdir(tmp_path))


def test_train_launcher_resume(tmp_path):
    """Kill-and-restart: the second invocation must resume, not restart."""
    r1 = _run(
        [
            "-m", "repro.launch.train", "--arch", "olmo-1b", "--smoke",
            "--steps", "6", "--batch", "4", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
        ]
    )
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = _run(
        [
            "-m", "repro.launch.train", "--arch", "olmo-1b", "--smoke",
            "--steps", "10", "--batch", "4", "--seq", "16",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "3",
        ]
    )
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] from step" in r2.stdout


def test_serve_launcher(tmp_path):
    r = _run(
        [
            "-m", "repro.launch.serve", "--arch", "gemma-2b", "--smoke",
            "--batch", "2", "--prompt-len", "16", "--max-new", "8",
        ]
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "tok/s" in r.stdout


def test_hierarchical_launcher_path(tmp_path):
    """The beyond-paper optimized data plane end-to-end (shard_map)."""
    r = _run(
        [
            "-m", "repro.launch.train", "--arch", "olmo-1b", "--smoke",
            "--steps", "4", "--batch", "4", "--seq", "16",
            "--hierarchical", "--zero1",
        ]
    )
    assert r.returncode == 0, r.stderr[-2000:]


@pytest.mark.slow
def test_dryrun_subprocess_small_mesh(tmp_path):
    """The dry-run machinery on a tiny forced-device mesh (8 devices) —
    proves lower+compile+roofline extraction works end to end without the
    512-device cost.  Uses a one-off script because XLA_FLAGS must be set
    before jax import."""
    script = tmp_path / "mini_dryrun.py"
    script.write_text(
        """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.launch.dryrun import run_cell
from repro.launch.mesh import make_mesh
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
rec = run_cell("olmo-1b", "train_4k", mesh, out_dir=%r)
assert rec["ok"], rec.get("error")
assert rec["hlo_flops"] > 0 and rec["collective_bytes"] > 0
print("MINI-DRYRUN-OK", rec["bottleneck"])
"""
        % str(tmp_path)
    )
    r = _run([str(script)], timeout=900)
    assert r.returncode == 0, (r.stdout[-1000:], r.stderr[-2000:])
    assert "MINI-DRYRUN-OK" in r.stdout
