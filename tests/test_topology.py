"""ClusterSpec arithmetic (§3.1), OCSConfig feasibility, and the two
physical topologies' L2-compatibility predicates (§4.1, §2.3)."""
import numpy as np
import pytest

from repro.core.topology import (
    ClusterSpec,
    CrossWiring,
    OCSConfig,
    Uniform,
    demand_feasible,
)


def test_spec_derived_sizes():
    spec = ClusterSpec(num_pods=4, k_spine=8, k_leaf=8, tau=2)
    assert spec.leaves_per_pod == 4
    assert spec.spines_per_pod == 4
    assert spec.gpus_per_pod == 32
    assert spec.num_gpus == 128  # the paper's testbed (§5)
    assert spec.num_ocs_groups == 4
    assert spec.ocs_per_group == 8


def test_spec_131k_gpu_claim():
    """Paper §3.1 Remark: >131k GPUs with 512-port OCSes."""
    spec = ClusterSpec(num_pods=512, k_spine=16, k_leaf=16, tau=1, k_ocs=512)
    assert spec.num_gpus == 512 * 256 >= 131_072


def test_spec_validation():
    with pytest.raises(ValueError):
        ClusterSpec(num_pods=2, k_spine=7)  # odd K_spine
    with pytest.raises(ValueError):
        ClusterSpec(num_pods=600, k_ocs=512)  # more pods than OCS ports
    with pytest.raises(ValueError):
        ClusterSpec(num_pods=2, k_leaf=8, tau=3)  # tau must divide K_leaf


def test_ocs_config_validate():
    spec = ClusterSpec(num_pods=3, k_spine=4, k_leaf=4)
    cfg = OCSConfig(spec)
    cfg.x[0, 0, 0, 1] = 1
    cfg.x[0, 0, 1, 2] = 1
    cfg.validate()
    cfg.x[0, 0, 0, 2] = 1  # pod 0 egress used twice on OCS 0
    with pytest.raises(AssertionError):
        cfg.validate()


def test_cross_wiring_l2():
    spec = ClusterSpec(num_pods=3, k_spine=4, k_leaf=4)
    cw = CrossWiring(spec)
    cfg = OCSConfig(spec)
    # even OCS carries i->j, paired odd OCS must carry the transpose
    cfg.x[0, 0, 0, 1] = 1
    assert not cw.l2_feasible(cfg)
    cfg.x[0, 1, 1, 0] = 1
    assert cw.l2_feasible(cfg)


def test_uniform_l2():
    spec = ClusterSpec(num_pods=3, k_spine=4, k_leaf=4)
    un = Uniform(spec)
    cfg = OCSConfig(spec)
    cfg.x[0, 0, 0, 1] = 1
    assert not un.l2_feasible(cfg)  # not symmetric
    cfg.x[0, 0, 1, 0] = 1
    assert un.l2_feasible(cfg)
    cfg.x[0, 1, 2, 2] = 1  # self-loop
    assert not un.l2_feasible(cfg)


def test_demand_feasible():
    spec = ClusterSpec(num_pods=3, k_spine=4, k_leaf=4)
    H = spec.num_ocs_groups
    C = np.zeros((H, 3, 3), dtype=np.int64)
    C[:, 0, 1] = C[:, 1, 0] = 2
    assert demand_feasible(C, spec)
    C[:, 0, 2] = 3  # asymmetric
    assert not demand_feasible(C, spec)
    C[:, 2, 0] = 3
    assert not demand_feasible(C, spec)  # row sum 5 > K_spine=4


def test_realized_bidirectional():
    spec = ClusterSpec(num_pods=3, k_spine=4, k_leaf=4)
    cfg = OCSConfig(spec)
    cfg.x[0, 0, 0, 1] = 1  # i->j only: no bidirectional link
    r = cfg.realized_bidirectional()
    assert r[0, 0, 1] == 0
    cfg.x[0, 1, 1, 0] = 1  # now j->i exists too
    r = cfg.realized_bidirectional()
    assert r[0, 0, 1] == 1 and r[0, 1, 0] == 1


def test_dark_pairs_is_make_before_break():
    """The switching window darkens a pod pair only when NO circuit on
    that pair survives in place (same group/OCS slot): a surviving
    circuit keeps carrying traffic while its neighbours retune, and a
    pair the new config doesn't route over has nothing to darken.
    ``changed_pairs`` (any |Δx| on the pair) stays the conservative
    superset used for pricing retune *work*."""
    spec = ClusterSpec(num_pods=4, k_spine=2, k_leaf=8)
    old = OCSConfig(spec, num_groups=1)
    new = OCSConfig(spec, num_groups=1)
    old.x[0, 0, 0, 1] = 1   # pair (0,1): two circuits …
    old.x[0, 1, 0, 1] = 1
    old.x[0, 0, 2, 3] = 1   # pair (2,3): one circuit on OCS 0
    new.x[0, 0, 0, 1] = 1   # … one survives in place → (0,1) stays lit
    new.x[0, 1, 0, 2] = 1   # new pair (0,2): must tune up → dark
    new.x[0, 1, 2, 3] = 1   # (2,3) moved OCS 0 → 1: retunes → dark
    assert new.dark_pairs(old) == frozenset({(0, 2), (2, 3)})
    # the lost (0,1) circuit and the removals still count as retune work
    assert new.rewiring_distance(old) == 4
    assert (0, 1) in new.changed_pairs(old)
    # identical configs: nothing retunes, nothing darkens
    assert new.dark_pairs(new.copy()) == frozenset()
    # direction is collapsed: a reverse-direction survivor keeps the
    # undirected pair lit
    rev = OCSConfig(spec, num_groups=1)
    rev.x[0, 0, 1, 0] = 1
    both = OCSConfig(spec, num_groups=1)
    both.x[0, 0, 1, 0] = 1  # survives
    both.x[0, 1, 0, 1] = 1  # forward circuit added on the same pair
    assert both.dark_pairs(rev) == frozenset()
