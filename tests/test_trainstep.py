"""Distributed train steps on the host mesh: loss decreases, grad-accum
equivalence, hierarchical (shard_map) path agrees with plain pjit."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_host_mesh
from repro.models import get_api, smoke_config
from repro.train.data import DataConfig, SyntheticData
from repro.train.optimizer import OptConfig
from repro.train.trainstep import TrainHparams, make_train_state, make_train_step


def _setup(arch="olmo-1b", batch=8, seq=32):
    cfg = smoke_config(arch)
    api = get_api(cfg)
    mesh = make_host_mesh()
    data = SyntheticData(
        DataConfig(vocab_size=cfg.vocab_size, batch=batch, seq=seq, seed=0),
        model_cfg=cfg,
    )
    return cfg, api, mesh, data


def _sds(batch):
    return {k: jax.ShapeDtypeStruct(v.shape, v.dtype) for k, v in batch.items()}


def test_loss_decreases():
    cfg, api, mesh, data = _setup()
    opt = OptConfig(lr=5e-3, warmup_steps=5, total_steps=200, weight_decay=0.0)
    hp = TrainHparams()
    b0 = data.batch_at(0)
    step, s_shard, b_shard = make_train_step(api, cfg, opt, mesh, hp, _sds(b0))
    state = make_train_state(api, jax.random.PRNGKey(0))
    losses = []
    for i in range(40):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_grad_accum_equivalence():
    """grad_accum=2 must match grad_accum=1 on the same global batch."""
    cfg, api, mesh, data = _setup(batch=8, seq=16)
    opt = OptConfig(lr=1e-3, warmup_steps=1, weight_decay=0.0)
    b0 = data.batch_at(0)
    outs = []
    for accum in (1, 2):
        hp = TrainHparams(grad_accum=accum)
        step, *_ = make_train_step(api, cfg, opt, mesh, hp, _sds(b0))
        state = make_train_state(api, jax.random.PRNGKey(1))
        batch = {k: jnp.asarray(v) for k, v in b0.items()}
        state, m = step(state, batch)
        outs.append((state, float(m["loss"])))
    assert outs[0][1] == pytest.approx(outs[1][1], rel=1e-4)
    w1 = jax.tree_util.tree_leaves(outs[0][0]["params"])
    w2 = jax.tree_util.tree_leaves(outs[1][0]["params"])
    for a, b in zip(w1, w2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=2e-5
        )


def test_hierarchical_matches_pjit():
    """The shard_map hierarchical step must produce the same update as the
    pjit baseline (1-device mesh: collectives are identities)."""
    cfg, api, mesh, data = _setup(batch=4, seq=16)
    opt = OptConfig(lr=1e-3, warmup_steps=1, weight_decay=0.01)
    b0 = data.batch_at(0)
    states = []
    for hier in (False, True):
        hp = TrainHparams(hierarchical=hier, zero1=True)
        step, *_ = make_train_step(api, cfg, opt, mesh, hp, _sds(b0))
        state = make_train_state(api, jax.random.PRNGKey(2))
        batch = {k: jnp.asarray(v) for k, v in b0.items()}
        state, m = step(state, batch)
        states.append((state, float(m["loss"])))
    assert states[0][1] == pytest.approx(states[1][1], rel=1e-4)
    for a, b in zip(
        jax.tree_util.tree_leaves(states[0][0]["params"]),
        jax.tree_util.tree_leaves(states[1][0]["params"]),
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32), atol=3e-5
        )


def test_train_resume_from_checkpoint(tmp_path):
    """Checkpoint mid-run, restart, continue: the loss stream must continue
    exactly (deterministic data + bitwise state restore)."""
    from repro.ckpt.manager import restore_checkpoint, save_checkpoint

    cfg, api, mesh, data = _setup(batch=4, seq=16)
    opt = OptConfig(lr=1e-3, warmup_steps=2, weight_decay=0.0)
    hp = TrainHparams()
    b0 = data.batch_at(0)
    step, *_ = make_train_step(api, cfg, opt, mesh, hp, _sds(b0))

    state = make_train_state(api, jax.random.PRNGKey(0))
    ref_losses = []
    for i in range(6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state, m = step(state, batch)
        ref_losses.append(float(m["loss"]))
        if i == 2:
            save_checkpoint(str(tmp_path), i, state)

    # crash + restart after step 2
    state2 = restore_checkpoint(
        str(tmp_path), jax.eval_shape(lambda: make_train_state(api, jax.random.PRNGKey(0)))
    )
    resumed = []
    for i in range(3, 6):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(i).items()}
        state2, m = step(state2, batch)
        resumed.append(float(m["loss"]))
    np.testing.assert_allclose(resumed, ref_losses[3:], rtol=1e-5)
